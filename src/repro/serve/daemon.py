"""The ops daemon: one serving-mode DARIS engine behind a unix socket.

Architecture — single-owner engine, journaled acks, wall-paced clock:

* The **pump thread** (the thread that calls ``run()``) is the ONLY
  thread that touches the engine. Socket handler threads turn client
  requests into commands on a queue and wait for the pump's reply, so
  scheduler state needs no locks.
* Every accepted submission is **journaled before it is acknowledged**:
  an acked request survives any crash (resume re-injects it). Release
  stamps are strictly monotonic virtual times, so live processing order
  equals journal order equals replay order — the bit-exactness hook.
* The sim backend's **virtual clock is paced by the wall clock**
  (``time_scale`` virtual ms per wall ms): the pump's frontier only ever
  moves to "what wall time says should have happened by now", so an idle
  daemon's virtual clock pauses instead of slamming to the horizon.

Lifecycle: SIGTERM/SIGINT checkpoint scheduler state (atomic write) and
exit WITHOUT draining — journaled-but-unfinished requests are the
restart's responsibility. The ``drain`` verb is the graceful path: stop
accepting, finish everything in flight, journal the final summary.
"""
from __future__ import annotations

import itertools
import os
import queue
import signal
import socket
import threading
import time
from typing import Dict, Optional

from ..api import DarisServer
from .config import build_server, check_schedulability
from .journal import (Journal, TERMINAL_STATUSES, fsck_journal,
                      read_journal, unfinished_submits)

_POLL_S = 0.02          # pump period while idle
_RESULT_POLL_S = 0.005  # handler-thread wait granularity for `result`


class ServeDaemon:
    """Long-running serving front-end over one ``DarisServer``."""

    def __init__(self, cfg: Dict, *, socket_path: str, journal_path: str,
                 checkpoint_path: Optional[str] = None,
                 tick_ms: float = 0.125, time_scale: float = 1.0,
                 fsync: bool = False):
        self.cfg = cfg
        self.socket_path = str(socket_path)
        self.checkpoint_path = checkpoint_path
        self.tick_ms = float(tick_ms)
        self.time_scale = float(time_scale)
        # opt-in static schedulability gate, BEFORE any engine exists:
        # "enforce" refuses to start an HP-unschedulable config (raises
        # UnschedulableError), "warn" reports and proceeds
        self.schedcheck_report = check_schedulability(cfg)
        if self.schedcheck_report is not None:
            print(f"[daemon] schedcheck: HP "
                  f"{self.schedcheck_report.hp_verdict} "
                  f"(overall {self.schedcheck_report.verdict})")
        self.server: DarisServer = build_server(cfg)

        # ---- resume: journal first (what was promised), checkpoint
        # second (what was learned) — promises outrank learned state
        self._pending_resubmit = []
        base_t, base_seq = 0.0, 0
        if os.path.exists(journal_path) \
                and os.path.getsize(journal_path) > 0:
            fsck = fsck_journal(journal_path)
            if fsck["kind"] == "mid-file":
                # a torn TAIL is a normal crash artifact (tolerated);
                # valid records AFTER damage mean acknowledged work would
                # be silently dropped on resume — refuse, never guess
                raise RuntimeError(
                    f"journal {journal_path} is corrupt mid-file (first "
                    f"bad line {fsck['bad_line']}, valid records follow "
                    f"it): refusing to resume. Inspect and repair with "
                    f"`python -m repro.serve fsck --journal "
                    f"{journal_path}` (add --yes to truncate to the "
                    f"last valid prefix).")
            records = read_journal(journal_path)
            stamps = [r["at_ms"] for r in records if "at_ms" in r]
            seqs = [r["seq"] for r in records if "seq" in r]
            base_t = max(stamps) if stamps else 0.0
            base_seq = max(seqs) + 1 if seqs else 0
            self._pending_resubmit = unfinished_submits(records)
        if checkpoint_path and os.path.exists(checkpoint_path):
            self.server.load_state(checkpoint_path)

        self.journal = Journal(
            journal_path, fsync=fsync,
            chaos=getattr(self.server.core, "_chaos", None))
        self._degrade_seen = 0    # chaos transitions already journaled
        self._seq = itertools.count(base_seq)
        self._last_t = base_t          # latest stamped virtual instant
        self._virt0 = base_t           # virtual time at daemon start
        self._wall0 = time.monotonic()
        self._handles: Dict[int, object] = {}   # seq -> SubmitHandle
        self._open: set = set()        # seqs with no terminal journal rec
        self._cmd_q: "queue.Queue" = queue.Queue()
        self._conn_lock = threading.Lock()
        self._n_conns = 0              # handler threads mid-conversation
        self._draining = False
        self._stop = False
        self._term = False             # signal flag (checkpoint + exit)
        self._sock: Optional[socket.socket] = None
        self.final_metrics = None
        # DSAN race guard (analysis/races.py): installed by run() on the
        # pump thread when sanitizing — construction-time work above
        # (build_server/load_state) legally ran on the constructing
        # thread, which may differ
        self.race_guard = None

    # -------------------------------------------------------------- clock
    def _wall_virtual(self) -> float:
        """Virtual ms the wall clock has earned since start."""
        return (self._virt0
                + (time.monotonic() - self._wall0) * 1000.0
                * self.time_scale)

    def _stamp(self) -> float:
        """Strictly monotonic virtual stamp for the next release/cancel:
        wall-paced, but never a repeat — distinct stamps mean the replay
        heap can never reorder same-instant submissions."""
        self._last_t = max(self._wall_virtual(),
                           self._last_t + self.tick_ms)
        return self._last_t

    # ---------------------------------------------------------- lifecycle
    def run(self) -> None:
        """Serve until ``drain``/``shutdown``/SIGTERM. Blocks; call from
        the process main thread (signal handlers are installed there)."""
        if self.cfg.get("sanitize") or \
                os.environ.get("DARIS_SANITIZE", "") not in ("", "0"):
            # the caller of run() IS the pump thread: bind ownership here
            # so every scheduler-mutating server call off this thread
            # raises a tsan-style RaceViolation
            from ..analysis.races import ThreadAffinityGuard
            self.race_guard = ThreadAffinityGuard(self.server).install()
        self.server.begin_serving()
        self._resubmit_pending()
        try:
            signal.signal(signal.SIGTERM, self._on_signal)
            signal.signal(signal.SIGINT, self._on_signal)
        except ValueError:
            pass    # not the main thread (tests drive run() directly)
        self._open_socket()
        try:
            while not self._stop:
                try:
                    cmd = self._cmd_q.get(timeout=_POLL_S)
                except queue.Empty:
                    cmd = None
                if cmd is not None:
                    self._handle_cmd(*cmd)
                if self._stop:
                    break
                self.server.pump(max(self._wall_virtual(), self._last_t))
                self._harvest()
                if self._term:
                    self._checkpoint()
                    break
        finally:
            # let handler threads flush their replies (the drain/shutdown
            # ack races process exit otherwise — the client would see the
            # connection close with no reply)
            deadline = time.monotonic() + 2.0
            while self._n_conns > 0 and time.monotonic() < deadline:
                time.sleep(0.01)
            self._close_socket()
            self.journal.close()

    def _on_signal(self, signum, frame) -> None:
        self._term = True

    def _resubmit_pending(self) -> None:
        """Re-inject journaled-but-unfinished submissions under their
        ORIGINAL seqs (the zero-lost contract: an acked seq keeps its
        identity across restarts)."""
        for rec in self._pending_resubmit:
            t = self._stamp()
            self.journal.append({"rec": "resubmitted", "seq": rec["seq"],
                                 "at_ms": t})
            try:
                h = self.server.request(rec["task"], at_ms=t,
                                        tenant=rec.get("tenant"))
            except KeyError:
                # config no longer serves this task: terminally reject so
                # the seq doesn't haunt every future restart
                self.journal.append({"rec": "done", "seq": rec["seq"],
                                     "status": "rejected",
                                     "response_ms": None})
                continue
            self._handles[rec["seq"]] = h
            self._open.add(rec["seq"])
        self._pending_resubmit = []

    def _checkpoint(self) -> None:
        if not self.checkpoint_path:
            return
        try:
            path = self.server.save_state(self.checkpoint_path)
            self.journal.append({"rec": "checkpoint", "path": path,
                                 "at_ms": self._last_t})
        except NotImplementedError:
            pass    # cluster engines: journal replay alone covers restart

    # ------------------------------------------------------------ commands
    def _handle_cmd(self, op: str, payload: Dict, reply_q) -> None:
        try:
            reply = getattr(self, f"_cmd_{op}")(payload)
        except Exception as e:   # noqa: BLE001 — daemon must survive
            reply = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        reply_q.put(reply)

    def _cmd_submit(self, payload: Dict) -> Dict:
        if self._draining or self._term:
            return {"ok": False, "error": "draining: not accepting work"}
        name = payload["task"]
        self.server.task_named(name)     # KeyError before any journaling
        seq = next(self._seq)
        t = self._stamp()
        # journal BEFORE ack: once the client sees this seq, a crash
        # cannot lose the request
        self.journal.append({"rec": "submit", "seq": seq, "task": name,
                             "tenant": payload.get("tenant"),
                             "prio": self.server.task_named(name).priority,
                             "at_ms": t})
        h = self.server.request(name, at_ms=t,
                                tenant=payload.get("tenant"))
        self._handles[seq] = h
        self._open.add(seq)
        # release synchronously: the reply carries the admission verdict
        self.server.pump(self._last_t)
        return {"ok": True, "seq": seq, "at_ms": t, "status": h.status}

    def _cmd_cancel(self, payload: Dict) -> Dict:
        seq = payload["seq"]
        h = self._handles.get(seq)
        if h is None:
            return {"ok": False, "error": f"unknown seq {seq}"}
        t = self._stamp()
        self.journal.append({"rec": "cancel", "seq": seq, "at_ms": t})
        self.server.cancel(h, at_ms=t)
        self.server.pump(self._last_t)   # resolve the outcome now
        self._harvest()
        return {"ok": True, "seq": seq, "status": h.status}

    def _cmd_stats(self, payload: Dict) -> Dict:
        snap = self.server.snapshot()
        return {"ok": True, "snapshot": snap,
                "submitted": len(self._handles),
                "open": len(self._open),
                "virtual_now_ms": self._last_t,
                "draining": self._draining}

    def _cmd_drain(self, payload: Dict) -> Dict:
        """Graceful end: refuse new work, finish everything accepted,
        journal the final summary."""
        self._draining = True
        m = self.server.end_serving(until_idle=True)
        self._harvest()
        self.final_metrics = m
        summary = m.summary()
        self.journal.append({"rec": "final", "summary": summary})
        self._stop = True
        return {"ok": True, "summary": summary,
                "lost": sorted(self._open)}

    def _cmd_shutdown(self, payload: Dict) -> Dict:
        """Fast stop: checkpoint, keep unfinished work journaled for the
        next start (the crash-with-manners path)."""
        self._checkpoint()
        self._stop = True
        return {"ok": True, "open": sorted(self._open)}

    # ------------------------------------------------------------- harvest
    def _harvest(self) -> None:
        """Journal terminal outcomes for every open submission, plus any
        new chaos degradation-mode transitions (ops forensics: the
        journal records WHEN the engine shed load and why)."""
        for seq in list(self._open):
            h = self._handles[seq]
            if h.status in TERMINAL_STATUSES:
                self.journal.append({"rec": "done", "seq": seq,
                                     "status": h.status,
                                     "response_ms": h.response_ms})
                self._open.discard(seq)
        ch = getattr(self.server.core, "_chaos", None)
        if ch is not None:
            while self._degrade_seen < len(ch.transitions):
                at_ms, frm, to = ch.transitions[self._degrade_seen]
                self.journal.append({"rec": "degrade", "from": frm,
                                     "to": to, "at_ms": at_ms})
                self._degrade_seen += 1

    # -------------------------------------------------------------- socket
    def _open_socket(self) -> None:
        if os.path.exists(self.socket_path):
            os.unlink(self.socket_path)
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._sock.bind(self.socket_path)
        self._sock.listen(16)
        self._sock.settimeout(0.2)
        threading.Thread(target=self._accept_loop, daemon=True).start()

    def _close_socket(self) -> None:
        if self._sock is not None:
            self._sock.close()
            self._sock = None
        if os.path.exists(self.socket_path):
            os.unlink(self.socket_path)

    def _accept_loop(self) -> None:
        sock = self._sock     # _close_socket may null the attribute
        while not self._stop:
            try:
                conn, _ = sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return    # socket closed during shutdown
            threading.Thread(target=self._handle_conn, args=(conn,),
                             daemon=True).start()

    def _handle_conn(self, conn: socket.socket) -> None:
        import json
        with self._conn_lock:
            self._n_conns += 1
        try:
            f = conn.makefile("rwb")
            line = f.readline()
            if not line:
                return
            try:
                req = json.loads(line.decode("utf-8"))
                reply = self._dispatch(req)
            except Exception as e:   # noqa: BLE001
                reply = {"ok": False,
                         "error": f"{type(e).__name__}: {e}"}
            f.write((json.dumps(reply) + "\n").encode("utf-8"))
            f.flush()
        finally:
            conn.close()
            with self._conn_lock:
                self._n_conns -= 1

    def _dispatch(self, req: Dict) -> Dict:
        """Route one client request. ``status``/``result``/``ping`` are
        read-only — handler threads answer them directly from handle
        state (only the pump mutates it). Everything else goes through
        the command queue to the pump thread."""
        op = req.get("op")
        if op == "ping":
            return {"ok": True}
        if op == "status":
            h = self._handles.get(req["seq"])
            if h is None:
                return {"ok": False, "error": f"unknown seq {req['seq']}"}
            return {"ok": True, "seq": req["seq"], **h.result()}
        if op == "result":
            return self._wait_result(req)
        if op in ("submit", "cancel", "stats", "drain", "shutdown"):
            rq: "queue.Queue" = queue.Queue(maxsize=1)
            self._cmd_q.put((op, req, rq))
            try:
                return rq.get(timeout=float(req.get("timeout_s", 60.0)))
            except queue.Empty:
                return {"ok": False, "error": "daemon busy: no reply"}
        return {"ok": False, "error": f"unknown op {op!r}"}

    def _wait_result(self, req: Dict) -> Dict:
        h = self._handles.get(req["seq"])
        if h is None:
            return {"ok": False, "error": f"unknown seq {req['seq']}"}
        deadline = time.monotonic() + float(req.get("timeout_s", 30.0))
        while not h.done and time.monotonic() < deadline:
            time.sleep(_RESULT_POLL_S)
        out = {"ok": h.done, "seq": req["seq"], **h.result()}
        if not h.done:
            out["error"] = "timeout: submission not terminal"
        return out
