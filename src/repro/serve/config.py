"""Serving config (JSON) -> ``DarisServer``, shared by daemon and replay.

The daemon and the offline journal replayer must build IDENTICAL engines
— same tasks in the same registration order, same geometry, same seed —
or a replay stops being a reproduction. This module is that single
construction path.

Config schema (all scheduler fields optional)::

    {
      "tasks": [
        {"dnn": "resnet18", "priority": "HP", "jps": 30.0,
         "count": 2, "tag": "-frontend"}
      ],
      "contexts": 4, "streams": 1, "oversubscribe": 4.0,
      "batching": {"max_batch": 8, "scope": "model"},
      "seed": 0, "noise": 0.06, "horizon_ms": 1e9
    }

``dnn`` names a calibrated profile (``serving.profiles``: resnet18, unet,
inceptionv3). Every task gets a ``ManualArrival`` — the daemon's clients
are the only release source — unless ``"jps_background": true`` marks it
as self-releasing periodic load behind the served traffic.
"""
from __future__ import annotations

import json
from typing import TYPE_CHECKING, Dict, List, Optional

from ..api import DarisServer, ManualArrival, ServerConfig

if TYPE_CHECKING:                                   # pragma: no cover
    from ..analysis.schedcheck import Report
from ..core.task import HP, LP, TaskSpec

_PRIO = {"HP": HP, "LP": LP, "hp": HP, "lp": LP}
# the daemon serves until stopped; the engine still wants a finite guard
# horizon for event validation, far past any realistic session
DEFAULT_HORIZON_MS = 1e9


def load_config(path: str) -> Dict:
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def _task_specs(cfg: Dict) -> List[Dict]:
    from ..serving.profiles import make_task
    out = []
    for t in cfg.get("tasks", []):
        prio = _PRIO[t.get("priority", "LP")]
        n = int(t.get("count", 1))
        for i in range(n):
            tag = t.get("tag", "")
            if n > 1:
                tag = f"{tag}-{i}"
            spec = make_task(t["dnn"], priority=prio,
                             jps=float(t.get("jps", 10.0)),
                             batch=int(t.get("batch", 1)), tag=tag)
            out.append({"spec": spec,
                        "background": bool(t.get("jps_background", False))})
    if not out:
        raise ValueError("serving config needs at least one task")
    return out


def server_config(cfg: Dict, *, arrivals: Optional[Dict[str, object]] = None
                  ) -> ServerConfig:
    """The (unbuilt) ``ServerConfig`` a serving config describes.
    ``arrivals`` swaps in replacement arrival processes by task name (the
    journal replayer's ``TraceArrival`` injection point); configured
    manual/background roles apply otherwise. The static analyzer
    (``repro.analysis.schedcheck``) consumes this directly — same object
    the daemon builds, so analysis and serving can never diverge."""
    sc = ServerConfig.sim()
    specs = _task_specs(cfg)
    overrides = arrivals or {}
    for entry in specs:
        spec: TaskSpec = entry["spec"]
        if spec.name in overrides:
            sc.task(spec, arrival=overrides[spec.name])
        elif entry["background"]:
            sc.task(spec)                   # default periodic self-release
        else:
            sc.task(spec, arrival=ManualArrival())
    sc.contexts(int(cfg.get("contexts", 4)))
    sc.streams(int(cfg.get("streams", 1)))
    sc.oversubscribe(float(cfg.get("oversubscribe", 4.0)))
    sc.horizon_ms(float(cfg.get("horizon_ms", DEFAULT_HORIZON_MS)))
    sc.seed(int(cfg.get("seed", 0)))
    # served traffic is aperiodic; phase offsets only apply to background
    # periodic tasks, and a daemon restart must not re-draw them — keep
    # the phase deterministic unless the config opts in
    sc.phase_offsets(bool(cfg.get("phase_offsets", False)))
    if "noise" in cfg:
        sc.noise(float(cfg["noise"]))
    b = cfg.get("batching")
    if b:
        sc.batching(max_batch=int(b.get("max_batch", 8)),
                    max_wait_ms=b.get("max_wait_ms"),
                    scope=b.get("scope", "model"))
    if "sched" in cfg:
        sc.scheduler_options(**cfg["sched"])
    c = cfg.get("chaos")
    if c:
        # {"chaos": {"seed": 0, "stage_fault_rate": 0.01, ...}} — the
        # same dict shape ChaosPlan takes; see chaos.plan.plan_from_dict
        from ..chaos.plan import plan_from_dict
        sc.chaos(plan_from_dict(c))
    s = cfg.get("sanitize")
    if s:
        # {"sanitize": 2} or {"sanitize": {"level": 1, "cadence": 64}};
        # the DARIS_SANITIZE env var still applies when the key is absent
        if isinstance(s, dict):
            sc.sanitize(level=int(s.get("level", 1)),
                        cadence=s.get("cadence"))
        else:
            sc.sanitize(level=int(s))
    return sc


def build_server(cfg: Dict, *, arrivals: Optional[Dict[str, object]] = None
                 ) -> DarisServer:
    """Build the serving engine a config describes (see
    ``server_config`` for the construction contract)."""
    return server_config(cfg, arrivals=arrivals).build()


def check_schedulability(cfg: Dict) -> Optional[Report]:
    """Opt-in startup gate: ``{"schedcheck": "warn" | "enforce"}``.

    Returns the analysis ``Report`` (or None when the key is absent /
    ``"off"``). ``"enforce"`` raises ``UnschedulableError`` when any HP
    task is statically UNSCHEDULABLE; ``"warn"`` only reports. The
    analyzer treats manual (client-driven) tasks at their declared rate,
    so the verdict is a contract on offered load, not a tautology."""
    mode = str(cfg.get("schedcheck", "off")).lower()
    if mode == "off":
        return None
    if mode not in ("warn", "enforce"):
        raise ValueError(f"schedcheck mode must be 'off', 'warn' or "
                         f"'enforce', got {mode!r}")
    from ..analysis.schedcheck import (UNSCHEDULABLE, UnschedulableError,
                                       analyze_config)
    report = analyze_config(server_config(cfg), label="serve-config")
    if mode == "enforce" and report.hp_verdict == UNSCHEDULABLE:
        raise UnschedulableError(report)
    return report
