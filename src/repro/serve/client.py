"""Thin client for the serving daemon: line-JSON over a unix socket.

One connection per call — the protocol is a single request line and a
single reply line, so there is no connection state to manage and a
crashed daemon can never wedge a client mid-stream.

    c = DarisClient("/tmp/daris.sock")
    seq = c.submit("resnet18-hp0", tenant="teamA")["seq"]
    c.status(seq)["status"]            # queued / running / ...
    c.result(seq, timeout_s=10.0)      # blocks until terminal
    c.cancel(seq)
    c.stats()["snapshot"]["queue_depth"]
    c.drain()                          # graceful: finish all, summarize
"""
from __future__ import annotations

import json
import socket
import time
from typing import Dict, Optional


class DaemonError(RuntimeError):
    """The daemon replied ``ok: false`` (the reply is attached)."""

    def __init__(self, reply: Dict):
        super().__init__(reply.get("error", "daemon error"))
        self.reply = reply


class DarisClient:
    """``connect_retries`` transient-failure retries on connect: a daemon
    mid-restart refuses connections for a moment, and a loaded one can
    time out the accept — both retryable. Backoff doubles from
    ``retry_backoff_s`` and is capped at ``retry_backoff_cap_s``; only
    the CONNECT is retried (a request that reached the daemon may have
    been acted on, so re-sending it is not idempotent)."""

    def __init__(self, socket_path: str, timeout_s: float = 60.0,
                 connect_retries: int = 3, retry_backoff_s: float = 0.05,
                 retry_backoff_cap_s: float = 1.0):
        self.socket_path = str(socket_path)
        self.timeout_s = timeout_s
        self.connect_retries = int(connect_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.retry_backoff_cap_s = float(retry_backoff_cap_s)

    # ------------------------------------------------------------- plumbing
    def _connect(self) -> socket.socket:
        delay = self.retry_backoff_s
        for attempt in range(self.connect_retries + 1):
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            s.settimeout(self.timeout_s)
            try:
                s.connect(self.socket_path)
                return s
            except (ConnectionRefusedError, socket.timeout):
                s.close()
                if attempt >= self.connect_retries:
                    raise
                time.sleep(delay)
                delay = min(delay * 2.0, self.retry_backoff_cap_s)
            except BaseException:
                s.close()
                raise
        raise ConnectionRefusedError(self.socket_path)  # unreachable

    def call(self, req: Dict, check: bool = True) -> Dict:
        s = self._connect()
        try:
            f = s.makefile("rwb")
            f.write((json.dumps(req) + "\n").encode("utf-8"))
            f.flush()
            line = f.readline()
        finally:
            s.close()
        if not line:
            raise DaemonError({"error": "connection closed without reply"})
        reply = json.loads(line.decode("utf-8"))
        if check and not reply.get("ok"):
            raise DaemonError(reply)
        return reply

    def wait_up(self, timeout_s: float = 10.0) -> None:
        """Block until the daemon answers ``ping`` (startup barrier)."""
        deadline = time.monotonic() + timeout_s
        while True:
            try:
                self.call({"op": "ping"})
                return
            except (OSError, DaemonError):
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"daemon at {self.socket_path} not up after "
                        f"{timeout_s}s")
                time.sleep(0.05)

    # ----------------------------------------------------------------- verbs
    def ping(self) -> Dict:
        return self.call({"op": "ping"})

    def submit(self, task: str, tenant: Optional[str] = None) -> Dict:
        return self.call({"op": "submit", "task": task, "tenant": tenant})

    def status(self, seq: int) -> Dict:
        return self.call({"op": "status", "seq": seq})

    def result(self, seq: int, timeout_s: float = 30.0) -> Dict:
        return self.call({"op": "result", "seq": seq,
                          "timeout_s": timeout_s})

    def cancel(self, seq: int) -> Dict:
        return self.call({"op": "cancel", "seq": seq})

    def stats(self) -> Dict:
        return self.call({"op": "stats"})

    def drain(self, timeout_s: float = 300.0) -> Dict:
        return self.call({"op": "drain", "timeout_s": timeout_s})

    def shutdown(self, timeout_s: float = 60.0) -> Dict:
        return self.call({"op": "shutdown", "timeout_s": timeout_s})
