"""repro.serve — production serving front-end over ``DarisServer``.

The paper's engine runs batch experiments: build, run to a horizon,
read metrics. This package wraps it as a long-running service:

* ``daemon``  — ops daemon: owns one serving-mode engine, accepts client
  commands over a local unix socket, journals every accepted submission
  durably before acknowledging it, checkpoints on SIGTERM, and resumes
  from checkpoint + journal after a crash with zero acknowledged-but-lost
  jobs.
* ``client``  — thin line-JSON client (``submit`` / ``status`` /
  ``result`` / ``cancel`` / ``stats`` / ``drain`` / ``shutdown``).
* ``journal`` — append-only JSONL request journal; replayable as
  ``TraceArrival`` input so any recorded traffic (outages included)
  becomes a deterministic simulation scenario.
* ``config``  — JSON serving config -> ``DarisServer`` builder, shared by
  the live daemon and the offline replayer so both drive the same engine.

CLI: ``python -m repro.serve daemon|submit|status|result|cancel|stats|
drain|shutdown|replay|audit``.
"""
from .client import DarisClient
from .config import build_server, load_config
from .daemon import ServeDaemon
from .journal import (Journal, audit_zero_lost, read_journal,
                      to_trace_arrivals, unfinished_submits)

__all__ = [
    "DarisClient", "ServeDaemon", "Journal",
    "build_server", "load_config",
    "read_journal", "to_trace_arrivals", "unfinished_submits",
    "audit_zero_lost",
]
