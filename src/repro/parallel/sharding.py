"""Sharding rules: param / activation / cache PartitionSpecs per arch.

Strategy (DESIGN.md §5): FSDP over ("pod","data"), tensor/expert parallel
over "model".

  * 2D weights [d, f]      -> P(fsdp, "model") (transposed for *_down/out)
  * attention [d, H, dh]   -> heads over "model" when n_heads % tp == 0,
                              replicated otherwise (tiny archs)
  * KV caches              -> kv-heads over "model" when divisible; else the
                              *sequence* axis shards over "model" so big
                              caches still fit (einsum attention contracts a
                              sharded axis -> GSPMD inserts the psum; the
                              shard_map flash-decode path in §Perf removes
                              the resulting all-gathers)
  * MoE experts [E, d, f]  -> E over "model" (expert parallelism)

Rules are path-pattern based so they apply to stacked layer params (leading
L axis gets None prepended automatically by rank matching).
"""
from __future__ import annotations

import copy
import math
import re
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P


def _join(*axes):
    """Combine axis names into one PartitionSpec entry, skipping Nones."""
    flat = []
    for a in axes:
        if a is None:
            continue
        if isinstance(a, (tuple, list)):
            flat.extend(a)
        else:
            flat.append(a)
    if not flat:
        return None
    return tuple(flat) if len(flat) > 1 else flat[0]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


class ShardingRules:
    def __init__(self, cfg, mesh, *, fsdp_axes=None, tp_axis: str = "model",
                 no_fsdp: bool = False, dp_only: bool = False,
                 mlp_fsdp: bool = False):
        """no_fsdp: params replicate across data (weight-stationary serving —
        kills per-step FSDP all-gathers). dp_only: the ``model`` axis joins
        data parallelism (tiny archs where TP-16 is pure collective waste)."""
        self.cfg = cfg
        self.mesh = mesh
        axis_names = mesh.axis_names
        if dp_only:
            tp_axis = "__none__"
            fsdp_axes = tuple(a for a in ("pod", "data", "model")
                              if a in axis_names)
        if fsdp_axes is None:
            fsdp_axes = tuple(a for a in ("pod", "data") if a in axis_names)
        dp_axes = fsdp_axes
        if no_fsdp:
            fsdp_axes = ()
        self.fsdp = (None if not fsdp_axes else
                     (fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]))
        self.tp = tp_axis if tp_axis in axis_names else None
        tp_size = mesh.shape[tp_axis] if self.tp else 1
        self.tp_size = tp_size
        self.shard_heads = bool(self.tp) and cfg.n_heads > 0 and cfg.n_heads % tp_size == 0
        self.shard_kv = bool(self.tp) and cfg.n_kv_heads > 0 and cfg.n_kv_heads % tp_size == 0
        self.shard_ssm_heads = (bool(self.tp) and cfg.ssm_state > 0
                                and cfg.ssm_nheads % tp_size == 0)
        self.mlp_fsdp = mlp_fsdp
        self.dp = (dp_axes if len(dp_axes) > 1 else dp_axes[0])  # batch axes
        self._dp_size = math.prod(
            mesh.shape[a] for a in ((self.dp,) if isinstance(self.dp, str)
                                    else self.dp))

    def for_batch(self, global_batch: int) -> "ShardingRules":
        """Batch-indivisible cells (long_500k B=1): batch replicates and the
        cache *sequence* axis takes over the data axes."""
        if global_batch % self._dp_size == 0:
            return self
        r = copy.copy(self)
        r.dp = None
        return r

    # -- parameters ---------------------------------------------------------
    def param_spec(self, path: str, ndim: int) -> P:
        spec = self._base_param_spec(path)
        if spec is None:
            return P()
        # stacked layers prepend L axes; pad spec with None on the left
        pad = ndim - len(spec)
        if pad > 0:
            spec = P(*([None] * pad), *spec)
        return spec

    def _base_param_spec(self, path: str) -> Optional[P]:
        c = self.cfg
        f, t = self.fsdp, self.tp
        heads = t if self.shard_heads else None
        kv = t if self.shard_kv else None
        ssm_h = t if self.shard_ssm_heads else None

        table = [
            # vocab-parallel embedding / head: d replicated so the logits
            # contraction needs no resharding (embedding tables are small
            # relative to HBM; FSDP-ing their d axis makes GSPMD unshard
            # the activation batch instead of gathering weights)
            (r"embed$", P(t, None)),
            (r"lm_head$", P(None, t)),
            # attention
            (r"attn/wq$", P(f, heads, None)),
            (r"attn/wk$", P(f, kv, None)),
            (r"attn/wv$", P(f, kv, None)),
            (r"attn/wo$", P(heads, None, f)),
            (r"attn/bq$", P(heads, None)),
            (r"attn/bk$", P(kv, None)),
            (r"attn/bv$", P(kv, None)),
            (r"attn/bo$", P(None,)),
            # MLA
            (r"attn/q_down$", P(f, None)),
            (r"attn/q_up$", P(None, heads, None)),
            (r"attn/kv_down$", P(f, None)),
            (r"attn/k_up$", P(None, heads, None)),
            (r"attn/v_up$", P(None, heads, None)),
            (r"attn/(q_norm|kv_norm)$", P(None,)),
            # mlp (gated + plain); mlp_fsdp = weight-gather MLP: weights
            # shard over BOTH axes on d, activations stay full-d batch-
            # sharded -> no TP all-reduce after the MLP (weight all-gather
            # traffic replaces the larger activation all-reduce)
            (r"mlp/w_gate$", P(_join(f, t), None) if self.mlp_fsdp else P(f, t)),
            (r"mlp/w_up$", P(_join(f, t), None) if self.mlp_fsdp else P(f, t)),
            (r"mlp/w_down$", P(None, _join(f, t)) if self.mlp_fsdp else P(t, f)),
            (r"mlp/w_in$", P(f, t)),
            (r"mlp/w_out$", P(t, f)),
            (r"mlp/b_in$", P(t,)),
            (r"mlp/b_out$", P(None,)),
            # MoE
            (r"moe/router$", P(f, None)),
            (r"moe/experts/w_gate$", P(t, f, None)),
            (r"moe/experts/w_up$", P(t, f, None)),
            (r"moe/experts/w_down$", P(t, None, f)),
            (r"moe/shared/w_gate$", P(f, t)),
            (r"moe/shared/w_up$", P(f, t)),
            (r"moe/shared/w_down$", P(t, f)),
            # mamba2
            (r"mamba/w_z$", P(f, ssm_h)),
            (r"mamba/w_x$", P(f, ssm_h)),
            (r"mamba/w_bc$", P(f, None)),
            (r"mamba/w_dt$", P(f, ssm_h)),
            (r"mamba/(dt_bias|A_log|D)$", P(ssm_h,)),
            (r"mamba/conv_x$", P(None, ssm_h)),
            (r"mamba/conv_x_b$", P(ssm_h,)),
            (r"mamba/conv_bc$", P(None, None)),
            (r"mamba/conv_bc_b$", P(None,)),
            (r"mamba/norm$", P(ssm_h,)),
            (r"mamba/w_out$", P(ssm_h, f)),
            # zamba2 shared block extras
            (r"shared_attn/wo_down$", P(f, None)),
            # norms and leftovers
            (r"(ln\w*|norm|final_norm|enc_norm|dec_norm)(/[wb])?$", P(None,)),
        ]
        for pat, spec in table:
            if re.search(pat, path):
                return spec
        return P()

    def _sanitize(self, spec: P, shape) -> P:
        """Drop axes whose mesh-size doesn't divide the dim (e.g. vocab
        50280 % 16 != 0 -> embed vocab axis replicates instead)."""
        out = []
        for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = math.prod(self.mesh.shape[a] for a in axes)
            out.append(entry if dim % size == 0 else None)
        return P(*out)

    def params_tree(self, params):
        return jax.tree_util.tree_map_with_path(
            lambda p, leaf: jax.sharding.NamedSharding(
                self.mesh, self._sanitize(
                    self.param_spec(_path_str(p), leaf.ndim), leaf.shape)),
            params)

    # -- activations / inputs ----------------------------------------------
    def tokens_spec(self) -> P:
        return P(self.dp, None)

    def embeds_spec(self) -> P:
        return P(self.dp, None, None)

    def logits_spec(self) -> P:
        return P(self.dp, None, self.tp)

    # -- caches --------------------------------------------------------------
    def cache_spec(self, path: str, ndim: int) -> P:
        """Stacked caches: leading L axis, then [B, S, KV, dh] etc."""
        t, dp = self.tp, self.dp
        # when batch is replicated (B=1 cells) the sequence axis absorbs the
        # data axes so the cache still shards across the whole pod
        seq_extra = self.fsdp if dp is None else None
        if re.search(r"(^|/)(k|v)$", path):
            if self.shard_kv:
                spec = P(dp, seq_extra, t, None)
            else:
                spec = P(dp, _join(seq_extra, t), None, None)  # seq-sharded KV
            return self._pad(spec, ndim)
        if re.search(r"(k_scale|v_scale)$", path):
            spec = (P(dp, seq_extra, t) if self.shard_kv
                    else P(dp, _join(seq_extra, t), None))
            return self._pad(spec, ndim)
        if re.search(r"latent$", path):
            return self._pad(P(dp, _join(seq_extra, t), None), ndim)
        if re.search(r"k_rope$", path):
            return self._pad(P(dp, _join(seq_extra, t), None), ndim)
        if re.search(r"state$", path):                # ssm state [B,H,P,N]
            h = t if self.shard_ssm_heads else None
            return self._pad(P(dp, h, None, None), ndim)
        if re.search(r"conv_(x|bc)$", path):
            h = t if self.shard_ssm_heads else None
            if path.endswith("conv_bc"):
                h = None
            return self._pad(P(dp, None, h), ndim)
        return self._pad(P(), ndim)                    # length, slots_pos

    def _pad(self, spec: P, ndim: int) -> P:
        pad = ndim - len(spec)
        if pad > 0:
            return P(*([None] * pad), *spec)
        return spec

    def cache_tree(self, cache):
        return jax.tree_util.tree_map_with_path(
            lambda p, leaf: jax.sharding.NamedSharding(
                self.mesh, self._sanitize(
                    self.cache_spec(_path_str(p), leaf.ndim), leaf.shape)),
            cache)

    def dist_ctx(self) -> dict:
        """Context dict the model threads through its forward passes:
        activation sharding constraints + shard_map MoE (DESIGN.md §5)."""
        return {
            "mesh": self.mesh, "dp": self.dp, "tp": self.tp,
            "tp_size": self.tp_size,
            "shard_heads": self.shard_heads, "shard_kv": self.shard_kv,
            "shard_ssm": self.shard_ssm_heads,
            "mlp_fsdp": self.mlp_fsdp,
            "vocab_tp": self.cfg.vocab_size % self.tp_size == 0,
            "dff_tp": (self.cfg.d_ff % self.tp_size == 0
                       if self.cfg.d_ff else False),
        }


class ActConstraint:
    """Activation sharding constraints at block boundaries — pins the
    layouts GSPMD would otherwise trade away (batch stays on dp, heads/ffn
    on tp), forcing weight all-gather FSDP instead of batch resharding."""

    def __init__(self, dist: Optional[dict]):
        self.d = dist

    def _c(self, x, *spec):
        if not self.d or self.d.get("mesh") is None:
            return x
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.d["mesh"], P(*spec)))

    def hidden(self, x):            # [B, S, d]
        if not self.d:
            return x
        # sequence parallelism (train cells): the residual stream shards its
        # seq axis over tp between blocks, so per-layer backward arenas
        # shard 16-way; GSPMD inserts the all-gather before attention and
        # the reduce-scatter after (Megatron SP pattern)
        if self.d.get("seq_shard") and x.ndim == 3 and                 x.shape[1] % self.d.get("tp_size", 1) == 0:
            return self._c(x, self.d["dp"], self.d["tp"], None)
        return self._c(x, self.d["dp"], None, None)

    def heads(self, x):             # [B, S, H, dh]
        if not self.d:
            return x
        tp = self.d["tp"] if self.d.get("shard_heads") else None
        return self._c(x, self.d["dp"], None, tp, None)

    def kv_heads(self, x):          # [B, S, KV, dh]
        if not self.d:
            return x
        tp = self.d["tp"] if self.d.get("shard_kv") else None
        return self._c(x, self.d["dp"], None, tp, None)

    def ffn(self, x):               # [B, S, d_ff]
        if not self.d:
            return x
        if self.d.get("mlp_fsdp"):
            return self._c(x, self.d["dp"], None, None)
        tp = self.d["tp"] if self.d.get("dff_tp") else None
        return self._c(x, self.d["dp"], None, tp)

    def logits(self, x):            # [B, S, V]
        if not self.d:
            return x
        tp = self.d["tp"] if self.d.get("vocab_tp") else None
        return self._c(x, self.d["dp"], None, tp)

    def ssm_heads(self, x):         # [B, L, H, P]
        if not self.d:
            return x
        tp = self.d["tp"] if self.d.get("shard_ssm") else None
        return self._c(x, self.d["dp"], None, tp, None)

    def ssm_inner(self, x):         # [B, L, d_inner]
        if not self.d:
            return x
        tp = self.d["tp"] if self.d.get("shard_ssm") else None
        return self._c(x, self.d["dp"], None, tp)
