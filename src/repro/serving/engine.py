"""Deprecated shim: ``RealtimeEngine`` now delegates to the unified runtime.

Real execution (worker threads running jitted stage functions on wall
clock, measured times feeding MRET) lives in ``RealtimeBackend``
(runtime/backend.py), driven by the same ``EngineCore`` loop as the
simulator. New code should construct servers through the ``repro.api``
facade:

    from repro.api import ServerConfig
    metrics = (ServerConfig.realtime().tasks(specs).contexts(2)
               .horizon_ms(4000).realtime_io(input_hw=32).build().run())

``staged_cnn_taskspec`` / ``staged_lm_taskspec`` (AFET-style calibration
of staged models into TaskSpecs with jitted payloads) still live here;
``RealtimeEngine`` remains importable for one release.
"""
from __future__ import annotations

import time
import warnings
from typing import Callable, List

import jax
import numpy as np

from ..core.metrics import RunMetrics
from ..core.scheduler import DarisScheduler
from ..core.task import StageProfile, TaskSpec
from ..models.cnn import StagedCNN
from ..runtime.arrivals import PeriodicArrival
from ..runtime.backend import RealtimeBackend
from ..runtime.engine_core import EngineCore

__all__ = ["RealtimeEngine", "staged_cnn_taskspec", "staged_lm_taskspec"]


def staged_cnn_taskspec(model: StagedCNN, *, priority: int, jps: float,
                        input_hw: int = 64, batch: int = 1,
                        tag: str = "", calibrate: bool = True,
                        n_sat: float = 40.0, mem_frac: float = 0.4) -> TaskSpec:
    """Wrap a StagedCNN into a TaskSpec whose stage payloads are jitted
    callables; t_alone is measured on this machine (AFET-style)."""
    x0 = np.zeros((batch, input_hw, input_hw, 3), np.float32)
    jitted = [jax.jit(st) for st in model.stages]
    payloads: List[Callable] = []
    times = []
    state = jax.device_put(x0)
    for st in jitted:
        fn = (lambda s, st=st: st(model.params, s))
        if calibrate:
            out = fn(state)
            jax.block_until_ready(out)           # compile
            t0 = time.perf_counter()
            out = fn(state)
            jax.block_until_ready(out)
            times.append((time.perf_counter() - t0) * 1000.0)
            state = out
        payloads.append(fn)
    if not calibrate:
        times = [1.0] * len(payloads)
    stages = [StageProfile(name=f"{model.name}/s{j}", t_alone_ms=t,
                           n_sat=n_sat, mem_frac=mem_frac, overhead_ms=0.05,
                           payload=payloads[j])
              for j, t in enumerate(times)]
    return TaskSpec(name=f"{model.name}{tag}", period_ms=1000.0 / jps,
                    priority=priority, stages=stages, batch=batch)


def staged_lm_taskspec(model, *, priority: int, jps: float,
                       n_stages: int = 4, prompt_len: int = 16,
                       batch: int = 2, tag: str = "",
                       n_sat: float = 40.0, mem_frac: float = 0.5
                       ) -> TaskSpec:
    """Wrap a staged LM decode step into a TaskSpec with real payloads.

    Each job is ONE decode step split across ``n_stages`` stage programs
    (``serving.staging.make_lm_stage_fns``). The inter-stage state that
    rides between payloads — and that ``RealtimeBackend`` reshards via
    ``serving.staging.migrate`` when the job crosses partitions — is the
    hidden activation plus the KV-cache slices touched so far: each stage
    pulls its layer slice from a prefilled donor cache with
    ``serving.staging.slice_cache`` and threads the updated slice
    forward, so a migration physically moves hidden AND cache, exactly
    the paper's zero-delay payload."""
    import jax.numpy as jnp

    from .staging import make_lm_stage_fns, slice_cache

    cfg = model.cfg
    params = model.init_params(0)
    stage_fns = make_lm_stage_fns(model, n_stages=n_stages)
    jitted = [jax.jit(fn) for fn in stage_fns]
    # prefill a donor cache once with the model's own forward; every job
    # then decodes one token against (its thread of) that cache
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (batch, prompt_len)))
    _, donor = model.prefill(
        params, {"tokens": tokens,
                 "cache": model.init_cache(batch, prompt_len + 1)})
    pos = jnp.asarray([prompt_len], dtype=jnp.int32)

    def make_payload(i):
        def payload(state):
            if state is None or not isinstance(state, dict):
                # fresh job: one new token per sequence
                state = {"hidden": jnp.zeros((batch, 1), jnp.int32),
                         "slices": {}}
            sl = state["slices"].get(i)
            if sl is None:
                sl = slice_cache(cfg, donor, i, n_stages)
            h, new_sl = jitted[i](params, state["hidden"], sl, pos)
            return {"hidden": h, "slices": {**state["slices"], i: new_sl}}
        return payload

    times = []
    state = None
    payloads = []
    for i in range(n_stages):
        fn = make_payload(i)
        out = fn(state)                           # compile
        jax.block_until_ready(out["hidden"])
        t0 = time.perf_counter()
        out = fn(state)
        jax.block_until_ready(out["hidden"])
        times.append((time.perf_counter() - t0) * 1000.0)
        state = out
        payloads.append(fn)
    stages = [StageProfile(name=f"{cfg.name}/lm-s{j}", t_alone_ms=t,
                           n_sat=n_sat, mem_frac=mem_frac,
                           overhead_ms=0.05, payload=payloads[j])
              for j, t in enumerate(times)]
    return TaskSpec(name=f"{cfg.name}{tag}", period_ms=1000.0 / jps,
                    priority=priority, stages=stages, batch=batch)


class RealtimeEngine:
    """Thin deprecated wrapper: EngineCore + RealtimeBackend with the
    historic constructor signature. Prefer ``repro.api.DarisServer``."""

    def __init__(self, sched: DarisScheduler, horizon_ms: float,
                 input_hw: int = 64, batch: int = 1):
        warnings.warn(
            "RealtimeEngine is deprecated; build a server via repro.api."
            "ServerConfig.realtime() instead", DeprecationWarning,
            stacklevel=2)
        self.core = EngineCore(
            sched, RealtimeBackend(input_hw=input_hw, batch=batch),
            horizon_ms=horizon_ms,
            arrivals={t.index: PeriodicArrival(phase_ms=0.0)
                      for t in sched.tasks})
        self.sched = sched

    @property
    def metrics(self) -> RunMetrics:
        return self.core.metrics

    def run(self) -> RunMetrics:
        return self.core.run()
