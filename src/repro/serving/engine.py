"""Real-execution serving engine: DARIS over jitted stage functions.

The same ``DarisScheduler`` that drives the simulator here dispatches real
XLA computations on wall-clock time: worker threads own lanes (XLA releases
the GIL, so lanes genuinely overlap), stage completions feed MRET with
*measured* times, and the admission/migration/priority machinery runs
unmodified. This is the laptop-scale validation path (DESIGN.md §2); on a
pod each lane maps to a sub-mesh program queue instead of a thread.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from ..core.metrics import RunMetrics, empty_metrics
from ..core.scheduler import DarisScheduler
from ..core.task import HP, LP, StageProfile, TaskSpec
from ..models.cnn import BUILDERS, StagedCNN


def staged_cnn_taskspec(model: StagedCNN, *, priority: int, jps: float,
                        input_hw: int = 64, batch: int = 1,
                        tag: str = "", calibrate: bool = True,
                        n_sat: float = 40.0, mem_frac: float = 0.4) -> TaskSpec:
    """Wrap a StagedCNN into a TaskSpec whose stage payloads are jitted
    callables; t_alone is measured on this machine (AFET-style)."""
    x0 = np.zeros((batch, input_hw, input_hw, 3), np.float32)
    jitted = [jax.jit(st) for st in model.stages]
    payloads: List[Callable] = []
    times = []
    state = jax.device_put(x0)
    for st in jitted:
        fn = (lambda s, st=st: st(model.params, s))
        if calibrate:
            out = fn(state)
            jax.block_until_ready(out)           # compile
            t0 = time.perf_counter()
            out = fn(state)
            jax.block_until_ready(out)
            times.append((time.perf_counter() - t0) * 1000.0)
            state = out
        payloads.append(fn)
    if not calibrate:
        times = [1.0] * len(payloads)
    stages = [StageProfile(name=f"{model.name}/s{j}", t_alone_ms=t,
                           n_sat=n_sat, mem_frac=mem_frac, overhead_ms=0.05,
                           payload=payloads[j])
              for j, t in enumerate(times)]
    return TaskSpec(name=f"{model.name}{tag}", period_ms=1000.0 / jps,
                    priority=priority, stages=stages, batch=batch)


class RealtimeEngine:
    """Wall-clock event loop + one worker thread per lane."""

    def __init__(self, sched: DarisScheduler, horizon_ms: float,
                 input_hw: int = 64, batch: int = 1):
        self.sched = sched
        self.horizon = horizon_ms / 1000.0
        self.input_hw = input_hw
        self.batch = batch
        self.metrics = empty_metrics(horizon_ms)
        self._lock = threading.Lock()
        self._done_q: "queue.Queue" = queue.Queue()
        # per-job intermediate state (activations between stages)
        self._job_state: Dict[int, object] = {}

    def _now_ms(self) -> float:
        return (time.perf_counter() - self._t0) * 1000.0

    def _worker(self, lane, inst):
        prof = inst.profile
        x = self._job_state.get(inst.job.job_id)
        if x is None:
            x = jax.device_put(np.zeros(
                (self.batch, self.input_hw, self.input_hw, 3), np.float32))
        t0 = time.perf_counter()
        out = prof.payload(x)
        jax.block_until_ready(out)
        et_ms = (time.perf_counter() - t0) * 1000.0
        self._job_state[inst.job.job_id] = out
        self._done_q.put((lane, inst, et_ms))

    def _dispatch_free_lanes(self):
        with self._lock:
            for lane in self.sched.free_lanes():
                inst = self.sched.next_for_lane(lane[0], self._now_ms())
                if inst is None:
                    continue
                inst.start_ms = self._now_ms()
                self.sched.lanes[lane] = inst
                threading.Thread(target=self._worker, args=(lane, inst),
                                 daemon=True).start()

    def run(self) -> RunMetrics:
        self._t0 = time.perf_counter()
        next_release = {t.index: 0.0 for t in self.sched.tasks}
        while True:
            now = self._now_ms()
            if now >= self.horizon * 1000.0:
                break
            # periodic releases
            with self._lock:
                for t in self.sched.tasks:
                    if now >= next_release[t.index]:
                        self.sched.on_release(t, now)
                        next_release[t.index] += t.spec.period_ms
            self._dispatch_free_lanes()
            # harvest completions
            try:
                lane, inst, et = self._done_q.get(timeout=0.002)
            except queue.Empty:
                continue
            with self._lock:
                self.sched.lanes[lane] = None
                done = self.sched.on_stage_finish(inst, self._now_ms(), et)
            if done is not None:
                self._job_state.pop(done.job_id, None)
                p = done.task.priority
                self.metrics.completed[p] += 1
                resp = self._now_ms() - done.release_ms
                self.metrics.response_ms[p].append(resp)
                if self._now_ms() > done.abs_deadline_ms:
                    self.metrics.missed[p] += 1
            self._dispatch_free_lanes()
        self.metrics.migrations = self.sched.migrations
        for r in self.sched.rejections:
            self.metrics.rejected[r.priority] += 1
        return self.metrics
