"""Per-DNN execution profiles, calibrated against the paper's Table I ONLY.

Table I (RTX 2080 Ti, 224x224x3 input, JPS = jobs/sec):
    DNN          min JPS   max JPS (batched)   gain
    ResNet18       627        1025             1.63x
    ResNet50       250         433             1.73x
    UNet           241         260             1.08x
    InceptionV3    142         446             3.13x

Calibration mapping (DESIGN.md §2, contention model):
  * t_alone = 1000 / min_JPS ms                    (single stream, alone)
  * n_sat   = N_units / gain                       (batching gain comes from
               filling the SMs a single instance can't occupy: UNet is wide
               -> saturates nearly all, InceptionV3 narrow -> ~22)
  * mem_frac encodes the architecture narrative: UNet memory-heavy (skip
    connections), ResNets moderate, InceptionV3 compute-narrow.

Stages follow the paper: ResNet -> 4 logical stages; UNet -> 4 (enc x2,
bottleneck, dec); InceptionV3 -> 4 block groups. Stage time split uses the
blocks' relative FLOPs (approximate, stated per stage below).
"""
from __future__ import annotations

from typing import List

from ..core.task import StageProfile, TaskSpec
from ..runtime.contention import DeviceModel, speedup_curve

N_UNITS = 68.0          # RTX 2080 Ti SMs

TABLE1 = {
    # name: (min_jps, max_jps)
    "resnet18": (627.0, 1025.0),
    "resnet50": (250.0, 433.0),
    "unet": (241.0, 260.0),
    "inceptionv3": (142.0, 446.0),
}

MEM_FRAC = {"resnet18": 0.42, "resnet50": 0.40, "unet": 0.72,
            "inceptionv3": 0.22}

# relative per-stage work (4 stages each, sums to 1)
STAGE_SPLIT = {
    "resnet18": (0.30, 0.26, 0.24, 0.20),
    "resnet50": (0.28, 0.27, 0.25, 0.20),
    "unet": (0.22, 0.26, 0.28, 0.24),
    "inceptionv3": (0.30, 0.28, 0.24, 0.18),
}

OVERHEAD_MS = 0.015      # per-stage dispatch/sync cost (staging price)


def batching_gain(name: str) -> float:
    mn, mx = TABLE1[name]
    return mx / mn


def n_sat(name: str) -> float:
    return max(6.0, N_UNITS / batching_gain(name))


def t_alone_ms(name: str) -> float:
    return 1000.0 / TABLE1[name][0]


def effective_batch_profile(name: str, batch: int) -> tuple:
    """(t_alone_b, n_sat_b) for a batched instance: kernels widen with batch
    (n_sat grows, saturating at the device) and per-job gain follows the
    shared ``speedup_curve`` toward the Table I asymptote."""
    g_b = speedup_curve(batching_gain(name), batch)
    t_b = batch * t_alone_ms(name) / g_b
    ns_b = min(N_UNITS, n_sat(name) * (batch ** 0.7))
    return t_b, ns_b


def make_stages(name: str, batch: int = 1, n_stages: int = 4) -> List[StageProfile]:
    if batch > 1:
        # statically pre-batched spec: the gain is already folded into
        # t_alone, so dynamic batching on top would double-count it
        t_total, ns, gain = (*effective_batch_profile(name, batch), 1.0)
    else:
        t_total, ns = t_alone_ms(name), n_sat(name)
        gain = batching_gain(name)     # drives contention.batch_speedup
    split = STAGE_SPLIT[name][:n_stages]
    norm = sum(split)
    return [StageProfile(name=f"{name}/s{j}",
                         t_alone_ms=t_total * w / norm,
                         n_sat=ns, mem_frac=MEM_FRAC[name],
                         overhead_ms=OVERHEAD_MS, batch_gain=gain)
            for j, w in enumerate(split)]


def make_task(name: str, *, priority: int, jps: float, batch: int = 1,
              tag: str = "") -> TaskSpec:
    period = 1000.0 / jps
    return TaskSpec(name=f"{name}{tag}", period_ms=period, priority=priority,
                    stages=make_stages(name, batch), batch=batch)


def device() -> DeviceModel:
    return DeviceModel(n_units=N_UNITS, bubble=0.12)
