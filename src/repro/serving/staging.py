"""Stage partitioning for LM architectures (paper §III-B1 on transformers).

Splits a scan-stacked LM into ``n_stages`` contiguous layer groups; each
stage is a pure function (hidden, cache_slice) -> (hidden, cache_slice), so
DARIS can preempt/migrate between groups. Stage 0 owns the embedding;
the last stage owns final norm + logits. Zero-delay migration = device_put
of the inter-stage hidden (and the remaining stages' cache slices) onto the
target partition's sharding (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Callable, List

import jax
import jax.numpy as jnp

from ..models import transformer
from ..models.api import Model


def stage_boundaries(n_layers: int, n_stages: int) -> List[tuple]:
    per = n_layers // n_stages
    rem = n_layers % n_stages
    out = []
    lo = 0
    for i in range(n_stages):
        hi = lo + per + (1 if i < rem else 0)
        out.append((lo, hi))
        lo = hi
    return out


def _slice_stack(tree, lo: int, hi: int):
    return jax.tree.map(lambda l: l[lo:hi], tree)


def make_lm_stage_fns(model: Model, n_stages: int = 4) -> List[Callable]:
    """Stage callables for dense/vlm/moe/ssm LM families.

    stage_fn(params, hidden_or_tokens, cache_slice, positions)
      -> (hidden_or_logits, new_cache_slice)
    """
    cfg = model.cfg
    if cfg.family == "hybrid":
        raise NotImplementedError(
            "hybrid staging follows group boundaries; use n_stages == "
            "n_layers // attn_every")
    bounds = stage_boundaries(
        cfg.n_layers // (2 if cfg.local_global_alternating else 1), n_stages)

    def make(i):
        lo, hi = bounds[i]

        def stage(params, x, cache_slice, positions):
            if i == 0 and x.dtype in (jnp.int32, jnp.int64):
                x = transformer._embed(params, cfg, x)
            layers = _slice_stack(params["layers"], lo, hi)

            def block(carry, xs):
                xx, aux = carry
                lp, ca = xs
                if cfg.family == "moe":
                    xx, nc, a = transformer._moe_body(
                        lp, xx, cfg, positions, ca, 0, True, None)
                    return (xx, aux + a), nc
                if cfg.family == "ssm":
                    xx, nc = transformer._ssm_body(lp, xx, cfg, ca, False)
                    return (xx, aux), nc
                if cfg.local_global_alternating:
                    xx, ncl = transformer._dense_body(
                        lp["local"], xx, cfg, positions,
                        None if ca is None else ca["local"],
                        cfg.sliding_window, 0)
                    xx, ncg = transformer._dense_body(
                        lp["global"], xx, cfg, positions,
                        None if ca is None else ca["global"], 0, 0)
                    nc = None if ca is None else {"local": ncl, "global": ncg}
                    return (xx, aux), nc
                xx, nc = transformer._dense_body(lp, xx, cfg, positions,
                                                 ca, 0, 0)
                return (xx, aux), nc

            x, new_cache, _ = transformer._scan_layers(
                block, x, layers, cache_slice, "none")
            if i == n_stages - 1:
                x = transformer._logits(params, cfg, x)
            return x, new_cache

        return stage

    return [make(i) for i in range(n_stages)]


def slice_cache(cfg, cache, stage_idx: int, n_stages: int):
    """Cache slice owned by one stage (moe handled at its 'layers' level)."""
    n_scan = cfg.n_layers // (2 if cfg.local_global_alternating else 1)
    lo, hi = stage_boundaries(n_scan, n_stages)[stage_idx]
    tree = cache["layers"] if (cfg.family == "moe" and "layers" in cache) else cache
    return _slice_stack(tree, lo, hi)


def migrate(tree, target_shardings):
    """Zero-delay migration: reshard the inter-stage state onto the target
    partition at a stage boundary — no running program is interrupted."""
    return jax.device_put(tree, target_shardings)
