"""Task-set builders (paper Table II + mixed set + ratio variants).

Table II (150% overload vs the pure-batching upper baseline, 2:1 LP:HP):
    ResNet18     17 HP + 34 LP @ 30 JPS each   (51*30 = 1530 ~ 1.5*1025)
    UNet          5 HP + 10 LP @ 24 JPS each   (15*24 =  360 ~ 1.4*260)
    InceptionV3   9 HP + 18 LP @ 24 JPS each   (27*24 =  648 ~ 1.5*446)
"""
from __future__ import annotations

from typing import List

from ..core.task import HP, LP, TaskSpec
from .profiles import make_task

TABLE2 = {
    "resnet18": (17, 34, 30.0),
    "unet": (5, 10, 24.0),
    "inceptionv3": (9, 18, 24.0),
}


def table2_taskset(dnn: str, *, batch: int = 1,
                   load_scale: float = 1.0) -> List[TaskSpec]:
    n_hp, n_lp, jps = TABLE2[dnn]
    jps *= load_scale
    out = []
    for i in range(n_hp):
        out.append(make_task(dnn, priority=HP, jps=jps, batch=batch,
                             tag=f"-hp{i}"))
    for i in range(n_lp):
        out.append(make_task(dnn, priority=LP, jps=jps, batch=batch,
                             tag=f"-lp{i}"))
    return out


def mixed_taskset(*, load_scale: float = 1.0) -> List[TaskSpec]:
    """Paper §VI-D: all DNN types together (scaled to a comparable load)."""
    out = []
    for dnn, (n_hp, n_lp, jps) in TABLE2.items():
        jps *= load_scale
        for i in range(max(n_hp // 3, 1)):
            out.append(make_task(dnn, priority=HP, jps=jps, tag=f"-hp{i}"))
        for i in range(max(n_lp // 3, 1)):
            out.append(make_task(dnn, priority=LP, jps=jps, tag=f"-lp{i}"))
    return out


def ratio_taskset(dnn: str, hp_fraction: float, total: int, jps: float
                  ) -> List[TaskSpec]:
    """Paper §VI-I: vary the HP:LP ratio at a fixed offered load."""
    n_hp = round(total * hp_fraction)
    out = []
    for i in range(n_hp):
        out.append(make_task(dnn, priority=HP, jps=jps, tag=f"-hp{i}"))
    for i in range(total - n_hp):
        out.append(make_task(dnn, priority=LP, jps=jps, tag=f"-lp{i}"))
    return out
